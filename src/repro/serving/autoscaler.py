"""Closed-loop SLO autoscaler: the goodput control plane over ClusterSim.

Trinity's premise is that a shared vector pool can coexist with
prefill–decode disaggregation *without violating SLOs* as the retrieval
mix drifts; DistServe frames the allocation question as goodput per GPU
rather than raw throughput. The cluster sim has every actuator (instance
add/drain, replica spawn/checkpoint-intact drain) and every sensor
(TTFT/ITL windows, probe deadline misses, queue depths) — this module
closes the loop:

Signal plane
    Each control epoch the :class:`Autoscaler` publishes a
    :class:`ControlSignals` snapshot: rolling-window TTFT/ITL p95 (the
    incremental ``ClusterMetrics`` windows — the same stream the
    end-of-run ``summary()`` reads), the windowed probe deadline-miss
    rate ingested from the vector pool's completion log, per-pool queue
    depths, and goodput = requests completing inside SLO per GPU-second.

Controller
    A KEDA-style target tracker under a FIXED total-GPU budget: each
    pool's *pressure* is its queued work per serving instance divided by
    its setpoint (SLO overshoot terms fold in — decode ITL overshoot is
    attributed to the VECTOR pool when RAG stalls dominate it, because
    adding decode instances cannot fix tokens that are waiting on
    probes). Pressure above ``hot_factor`` makes a pool hungry; a unit
    comes from free budget or from a donor sitting below ``cold_factor``
    — two-sided hysteresis plus per-pool cooldowns (the PR-5
    rebalancer's anti-thrash idiom), at most one scale action per epoch.
    Scale-down is a SAFE DRAIN: vector replicas re-queue their in-flight
    children checkpoint-intact (``drain_replica``, the ``_move_replica``
    machinery), LLM instances stop admitting and finish their in-flight
    work (device KV never drops, zero re-prefills); serving minimums
    always hold. Stage-aware priority: decode deficits are served first,
    and a vector deficit may only take a decode unit while the windowed
    ITL p95 is inside ``itl_protect_factor`` × the TPOT SLO — a starved
    vector pool cannot starve decode ITL in turn.

Every decision lands in ``ClusterMetrics.scale_events`` (timestamp,
pool, delta, triggering signal) so benches and tests audit the full
trajectory. Knobs-off (``ClusterSim(autoscaler=None)``, the default):
nothing here is constructed and cluster behavior is bit-identical.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

from repro.configs.base import AutoscalerConfig
from repro.serving.request import RollingWindow

# ITL-overshoot attribution: when at least this fraction of decode time
# is RAG-stall wait, long token gaps are the vector pool's deficit, not
# decode's (more decode instances cannot speed up a stalled token)
_STALL_ATTRIBUTION = 0.5
# fraction of the TTFT budget prefill may spend clearing its token
# backlog before the pool reads hot (the rest is queueing + handoff)
_TTFT_HEADROOM = 0.5

_POOLS = ("decode", "prefill", "vector")  # stage-aware service order


@dataclasses.dataclass(frozen=True)
class ControlSignals:
    """One epoch's published signal snapshot (the controller's whole
    world view — also the bench's audit trail)."""

    t: float
    # rolling-window SLO attainment
    ttft_p95: float
    itl_p95: float
    probe_miss_rate: float  # windowed probe deadline-miss fraction
    decode_stall_frac: float  # RAG-stall share of decode time (feedback)
    # per-pool queue depths / capacity
    prefill_queue: int
    prefill_backlog_tokens: int  # queued + in-batch prompt tokens
    decode_queue: int
    vector_queue: int
    prefill_instances: int  # serving (alive, not draining/retired)
    decode_instances: int
    vector_replicas: int
    gpu_units: int
    # goodput objective
    finish_rate: float  # windowed completions / s
    goodput_rps: float  # windowed SLO-good completions / s
    slo_attainment: float  # goodput_rps / finish_rate (1.0 when idle)
    goodput_per_gpu: float  # goodput_rps / gpu_units
    # normalized target-tracking pressures (1.0 = at setpoint)
    prefill_pressure: float
    decode_pressure: float
    vector_pressure: float

    def pressure(self, pool: str) -> float:
        return getattr(self, f"{pool}_pressure")


class Autoscaler:
    """KEDA-style goodput reconciler bound to one :class:`ClusterSim`.

    The sim calls :meth:`epoch` on its event heap every
    ``cfg.epoch_s``; everything else is driven from there.
    """

    def __init__(self, sim, cfg: AutoscalerConfig):
        self.sim = sim
        self.cfg = cfg
        self.signals_log: List[ControlSignals] = []
        self.budget = int(cfg.gpu_budget) or sim.gpu_units()
        self._w_miss = RollingWindow(cfg.window_s)
        self._vcursor = 0  # cursor into vector_pool.metrics.completed
        self._last_up: Dict[str, float] = {p: -1e18 for p in _POOLS}
        self._last_down: Dict[str, float] = {p: -1e18 for p in _POOLS}
        # one in-flight LLM drain at a time: (recipient, reason, signal)
        # granted when the drained instance retires
        self._pending_grant: Optional[Tuple[str, str, float]] = None

    # ------------------------------------------------------- signal plane
    def _ingest_pool_completions(self, t: float):
        """Fold new vector-pool completions into the deadline-miss
        window (observation-time stamped: 'misses seen in the last
        window')."""
        comp = self.sim.vector_pool.metrics.completed
        while self._vcursor < len(comp):
            v = comp[self._vcursor]
            self._vcursor += 1
            if v.kind == "insert" or v.deadline is None \
                    or v.t_completed is None:
                continue
            miss = v.failed or v.t_completed > v.deadline
            self._w_miss.add(t, 1.0 if miss else 0.0)

    def _serving(self, pool) -> int:
        return sum(1 for i in pool if i.health.serving)

    def _prefill_tok_rate(self) -> float:
        """Per-instance prefill token throughput, profiled from a live
        instance's own timing model (its chips / contention / slowdown),
        the way a real controller profiles measured service rates."""
        insts = [i for i in self.sim.prefill_pool if i.health.serving] \
            or self.sim.prefill_pool
        return 4096.0 / max(insts[0].batch_time(4096), 1e-12)

    def snapshot(self, t: float) -> ControlSignals:
        sim, cfg = self.sim, self.cfg
        m = sim.metrics
        vpool = sim.vector_pool
        scheds = getattr(vpool, "schedulers", None) or [vpool.scheduler]

        ttft_p95 = m.window_ttft_p(95, t)
        itl_p95 = m.window_tpot_p(95, t)
        miss_rate = self._w_miss.mean(t)
        stall_frac = float(vpool.feedback.decode_stall_frac)
        q_pre = len(sim.prefill_queue)
        q_dec = len(sim.decode_queue)
        q_vec = sum(s.queued() for s in scheds)
        n_pre = self._serving(sim.prefill_pool)
        n_dec = self._serving(sim.decode_pool)
        n_vec = len(vpool.replicas)
        finish_rate = m.window_finish_rate(t)
        goodput = m.window_goodput(t, cfg.ttft_slo_s, cfg.tpot_slo_s)
        units = sim.gpu_units()
        # prefill backlog in TOKENS, queued + in-batch: prefill gulps its
        # whole queue into giant batches, so queue DEPTH goes blind the
        # moment a batch starts — clear-time of the token backlog is the
        # live signal
        backlog_tok = sum(r.prompt_len for r in sim.prefill_queue) \
            + sum(r.prompt_len for i in sim.prefill_pool
                  if i.health.serving for r in i.current)

        # target tracking: queued work per serving instance vs setpoint
        p_pre = q_pre / max(n_pre, 1) / cfg.queue_target
        p_dec = q_dec / max(n_dec, 1) / cfg.queue_target
        p_vec = max(q_vec / max(n_vec, 1) / cfg.queue_target_vector,
                    miss_rate / max(cfg.probe_miss_budget, 1e-9))
        # live prefill clear-time vs the TTFT headroom: how long the
        # current token backlog takes the serving instances to chew
        # through, against the slice of the TTFT budget prefill may spend
        clear_s = backlog_tok / max(n_pre * self._prefill_tok_rate(),
                                    1e-9)
        p_pre = max(p_pre,
                    clear_s / (_TTFT_HEADROOM * cfg.ttft_slo_s))
        # Windowed-TTFT overshoot folds in only while backlog exists:
        # the window lags (it sees finishes, not arrivals), and chasing
        # a stale overshoot after the backlog cleared would pin the pool
        # hot forever.
        if backlog_tok > 0 and ttft_p95 > 0:
            p_pre = max(p_pre, ttft_p95 / cfg.ttft_slo_s)
        # ITL overshoot goes to decode — unless RAG stalls dominate the
        # gaps, in which case the deficit is the vector pool's.
        if itl_p95 > 0:
            itl_term = itl_p95 / cfg.tpot_slo_s
            if stall_frac >= _STALL_ATTRIBUTION:
                p_vec = max(p_vec, itl_term)
            else:
                p_dec = max(p_dec, itl_term)

        return ControlSignals(
            t=t, ttft_p95=ttft_p95, itl_p95=itl_p95,
            probe_miss_rate=miss_rate, decode_stall_frac=stall_frac,
            prefill_queue=q_pre, prefill_backlog_tokens=backlog_tok,
            decode_queue=q_dec, vector_queue=q_vec,
            prefill_instances=n_pre, decode_instances=n_dec,
            vector_replicas=n_vec, gpu_units=units,
            finish_rate=finish_rate, goodput_rps=goodput,
            slo_attainment=(goodput / finish_rate if finish_rate > 0
                            else 1.0),
            goodput_per_gpu=goodput / max(units, 1),
            prefill_pressure=p_pre, decode_pressure=p_dec,
            vector_pressure=p_vec)

    # -------------------------------------------------------- controller
    def epoch(self):
        """One control epoch: publish signals, then reconcile (at most
        one scale action)."""
        t = self.sim.t_now
        self._ingest_pool_completions(t)
        sig = self.snapshot(t)
        self.signals_log.append(sig)
        self._reconcile(t, sig)

    def _reconcile(self, t: float, sig: ControlSignals):
        cfg = self.cfg
        for pool in _POOLS:  # decode ITL outranks prefill outranks vector
            if sig.pressure(pool) <= cfg.hot_factor:
                continue
            if t - self._last_up[pool] < cfg.cooldown_up_s:
                continue
            if self._try_grow(pool, t, sig):
                return  # one action per epoch (anti-thrash)

    def _try_grow(self, pool: str, t: float, sig: ControlSignals) -> bool:
        cfg = self.cfg
        if self._pending_grant is not None:
            return False  # a donated unit is already in flight
        reason = f"pressure:{pool}"
        signal = sig.pressure(pool)
        if self.sim.gpu_units() < self.budget:
            self._grant(pool, t, reason, signal)
            return True
        donors = []
        for q in _POOLS:
            if q == pool or sig.pressure(q) >= cfg.cold_factor:
                continue
            # pace donations AND never strip a pool that was itself
            # grown within the down-cooldown (up→down flapping)
            if t - self._last_down[q] < cfg.cooldown_down_s or \
                    t - self._last_up[q] < cfg.cooldown_down_s:
                continue
            if not self._can_shrink(q):
                continue
            if pool == "vector" and q == "decode" and \
                    sig.itl_p95 > cfg.itl_protect_factor * cfg.tpot_slo_s:
                continue  # a vector deficit must not starve decode ITL
            donors.append((sig.pressure(q), q))
        if not donors:
            return False
        _, donor = min(donors)
        return self._shrink(donor, pool, t, sig)

    def _can_shrink(self, pool: str) -> bool:
        sim, cfg = self.sim, self.cfg
        if pool == "prefill":
            return self._serving(sim.prefill_pool) > max(cfg.min_prefill, 1)
        if pool == "decode":
            return self._serving(sim.decode_pool) > max(cfg.min_decode, 1)
        return self._vector_drain_shard() is not False

    def _vector_drain_shard(self):
        """The shard a vector drain should come from: the coldest one
        above its serving floor (``cfg.min_vector`` raises the pool
        floors). None = monolithic pool with headroom; False = no
        replica can be drained anywhere."""
        pool = self.sim.vector_pool
        if hasattr(pool, "shards"):
            t = self.sim.t_now
            cands = [
                s for s in range(pool.shards.num_shards)
                if len(pool.shard_replicas(s)) > max(pool.shard_floor(s),
                                                     self.cfg.min_vector)]
            if not cands:
                return False
            return min(cands, key=lambda s: (pool.shard_load_score(s, t), s))
        if len(pool.replicas) > max(pool.drain_floor(),
                                    self.cfg.min_vector):
            return None
        return False

    def _shrink(self, donor: str, recipient: str, t: float,
                sig: ControlSignals) -> bool:
        reason = f"donate:{donor}->{recipient}"
        signal = sig.pressure(donor)
        if donor == "vector":
            shard = self._vector_drain_shard()
            if shard is False:
                return False
            if not self.sim.drain_vector_replica(shard=shard, reason=reason,
                                                 signal=signal):
                return False
            self._last_down["vector"] = t
            # checkpoint-intact drain frees the unit immediately
            self._grant(recipient, t, f"pressure:{recipient}",
                        sig.pressure(recipient))
            return True
        drain = (self.sim.drain_prefill_instance if donor == "prefill"
                 else self.sim.drain_decode_instance)
        inst = drain(reason=reason, signal=signal)
        if inst is None:
            return False
        self._last_down[donor] = t
        if inst.health.retired:
            # the donor was idle: retired on the spot, grant now
            self._grant(recipient, t, f"pressure:{recipient}",
                        sig.pressure(recipient))
        else:
            self._pending_grant = (recipient, f"pressure:{recipient}",
                                   sig.pressure(recipient))
        return True

    def _grant(self, pool: str, t: float, reason: str, signal: float):
        if pool == "prefill":
            self.sim.add_prefill_instance(reason=reason, signal=signal,
                                          kick=True)
        elif pool == "decode":
            self.sim.add_decode_instance(reason=reason, signal=signal,
                                         kick=True)
        else:
            self.sim.add_vector_replica(reason=reason, signal=signal)
        self._last_up[pool] = t

    # ---------------------------------------------------------- callbacks
    def on_drain_complete(self, pool_name: str, t: float):
        """A drained LLM instance emptied and retired — hand its freed
        unit to the waiting recipient (no-op for drains the controller
        did not initiate)."""
        if self._pending_grant is None:
            return
        recipient, reason, signal = self._pending_grant
        self._pending_grant = None
        self._grant(recipient, t, reason, signal)
