"""Prefill / decode pool instances (timing-model driven; the real-compute
path for small models lives in launch/serve.py and examples/).

Each instance owns its paged-KV budget; decode runs continuous batching at
token granularity (admit on any step boundary, free on completion) — the
LLM-side mirror of the vector engine's extend-granularity batching.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional

from repro.core import roofline_model
from repro.core.roofline_model import V5E, Hardware
from repro.serving.kv_cache import PagedKVManager, kv_bytes_per_token
from repro.serving.request import GenRequest


@dataclasses.dataclass
class InstanceHealth:
    slowdown: float = 1.0
    step_ewma: float = 0.0
    alive: bool = True
    # graceful scale-down (autoscaler): a draining instance stops taking
    # new admissions but finishes its in-flight work (device KV is
    # per-instance — a kill would force re-prefills, a drain loses
    # nothing); once empty it is retired and stops counting against the
    # GPU budget. Both always False outside an autoscaler drain.
    draining: bool = False
    retired: bool = False

    @property
    def serving(self) -> bool:
        """Eligible for NEW admissions (alive, not draining/retired)."""
        return self.alive and not self.draining and not self.retired


class PrefillInstance:
    def __init__(self, iid: int, model_cfg, chips: int,
                 max_batch_tokens: int = 65536, hw: Hardware = V5E,
                 capacity_factor: float = 1.0, contention: float = 1.0):
        self.iid = iid
        self.cfg = model_cfg
        self.chips = max(1, int(chips * capacity_factor))
        self.max_batch_tokens = max_batch_tokens
        self.hw = hw
        self.contention = contention
        self.health = InstanceHealth()
        self.busy_until = 0.0
        self.current: List[GenRequest] = []

    def batch_time(self, tokens: int) -> float:
        t = roofline_model.prefill_time(self.cfg, tokens, self.chips, self.hw)
        return t * self.contention * self.health.slowdown

    def start_batch(self, t_now: float, reqs: List[GenRequest]) -> float:
        tokens = sum(r.prompt_len for r in reqs)
        dt = self.batch_time(tokens)
        self.current = reqs
        self.busy_until = t_now + dt
        for r in reqs:
            r.t_prefill_start = t_now
        self.health.step_ewma = (0.8 * self.health.step_ewma + 0.2 * dt
                                 if self.health.step_ewma else dt)
        return self.busy_until


class DecodeInstance:
    def __init__(self, iid: int, model_cfg, chips: int, max_batch: int = 64,
                 kv_capacity_bytes: float = 16e9 * 8 * 0.5, hw: Hardware = V5E,
                 capacity_factor: float = 1.0, contention: float = 1.0,
                 ep_penalty: float = 0.0):
        self.iid = iid
        self.cfg = model_cfg
        self.chips = max(1, int(chips * capacity_factor))
        self.max_batch = max_batch
        self.hw = hw
        self.contention = contention
        self.ep_penalty = ep_penalty
        self.health = InstanceHealth()
        self.pager = PagedKVManager(kv_capacity_bytes, model_cfg)
        self.active: Dict[int, GenRequest] = {}
        self.stepping = False  # a step event is scheduled
        self.tokens_emitted = 0

    @property
    def free_slots(self) -> int:
        return self.max_batch - len(self.active)

    def can_admit(self, req: GenRequest) -> bool:
        return (self.free_slots > 0
                and self.pager.can_admit(req.prompt_len + req.max_new_tokens))

    def admit(self, req: GenRequest):
        assert self.pager.allocate(req.rid, req.prompt_len + req.max_new_tokens)
        self.active[req.rid] = req

    def release(self, req: GenRequest):
        self.pager.free(req.rid)
        self.active.pop(req.rid, None)

    def step_time(self, t_now: float) -> float:
        if not self.active:
            return 0.0
        ctxs = [r.prompt_len + r.tokens_out for r in self.active.values()]
        dt = roofline_model.decode_step_time(
            self.cfg, len(self.active), int(sum(ctxs) / len(ctxs)),
            self.chips, self.hw)
        dt = dt * self.contention * self.health.slowdown + self.ep_penalty
        self.health.step_ewma = (0.8 * self.health.step_ewma + 0.2 * dt
                                 if self.health.step_ewma else dt)
        return dt
