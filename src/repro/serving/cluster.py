"""Event-driven cluster simulator: PD-disaggregated LLM pools + the Trinity
vector pool, wired per a Fig. 2 placement.

All device-level math (engines, kernels, models) is real JAX elsewhere;
here queueing, links, failures and the closed control loop (u_kv, prefill
P95 wait, decode stalls → adaptive r/τ_pre) evolve in simulated time with
latencies from the calibrated roofline timing model. This is the harness
behind benchmarks/bench_architectures.py, bench_scheduler.py and
bench_semantic_cache.py.

Semantic answer cache (``pool_cfg.semantic_cache_enabled``): arrivals
first probe the vector pool with a ``cache_lookup``-class request over the
prompt embedding. A hit under the class score threshold serves the cached
answer immediately — no prefill, no KV transfer, no decode (TTFT = lookup
round trip; ``cache_hits``/``saved_prefill_tokens`` count the win). A miss
takes the normal PD path and, at completion, asynchronously inserts the
(prompt embedding → answer) pair into the pool's growable cache segment as
a deadline-less background-class request. Requests sharing a
``prompt_id`` embed identically, so repeated prompts hit.

Fault tolerance at pool level:
  · kill_prefill/kill_decode at time t — in-flight work re-queues; decode
    victims lose device KV and re-prefill (counted),
  · stragglers: slowdown factors; the dispatcher routes new work away from
    instances whose step EWMA exceeds ``straggler_factor``× the pool median,
  · elastic decode scaling on queue depth (optional).
"""
from __future__ import annotations

import heapq
import itertools
from collections import deque
from typing import Callable, Dict, List, Optional

import numpy as np

from repro.configs.base import AutoscalerConfig
from repro.core.architectures import make_placements
from repro.core.roofline_model import V5E, Hardware
from repro.core.scheduler import VectorRequest
from repro.core.trinity_pool import ShardedVectorPool, VectorPool
from repro.serving.autoscaler import Autoscaler
from repro.serving.engine import DecodeInstance, PrefillInstance
from repro.serving.kv_cache import kv_bytes_per_token
from repro.serving.kv_link import KVLink
from repro.serving.request import (ClusterMetrics, GenRequest, ScaleEvent,
                                   percentile)


class ClusterSim:
    def __init__(self, model_cfg, pool_cfg, db, graph, *,
                 placement: str = "disaggregated", policy: str = "trinity",
                 n_prefill: int = 2, n_decode: int = 4,
                 vector_replicas: int = 1, chips_per_instance: int = 8,
                 decode_batch: int = 32, kv_link_bw: float = 40e9,
                 hw: Hardware = V5E, poll_dt: float = 2e-4,
                 straggler_factor: float = 2.5, elastic_decode: bool = False,
                 autoscaler: Optional[AutoscalerConfig] = None,
                 use_pallas: Optional[bool] = False, seed: int = 0):
        self.cfg = model_cfg
        self.pool_cfg = pool_cfg
        self.hw = hw
        self.poll_dt = poll_dt
        self.placement = make_placements(hw, chips_per_instance)[placement]
        pl = self.placement

        self.prefill_pool = [
            PrefillInstance(i, model_cfg, chips_per_instance, hw=hw,
                            capacity_factor=pl.llm_capacity_factor_prefill,
                            contention=(pl.hbm_contention_factor
                                        if pl.llm_capacity_factor_prefill < 1
                                        else 1.0))
            for i in range(n_prefill)]
        self.decode_pool = [
            DecodeInstance(i, model_cfg, chips_per_instance,
                           max_batch=decode_batch, hw=hw,
                           capacity_factor=pl.llm_capacity_factor_decode,
                           contention=(pl.hbm_contention_factor
                                       if pl.llm_capacity_factor_decode < 1
                                       else 1.0),
                           ep_penalty=pl.ep_dispatch_penalty)
            for i in range(n_decode)]
        if pool_cfg is not None and pool_cfg.num_shards > 1:
            # sharded scatter–gather pool: the corpus is partitioned into
            # balanced-k-means shards (it may exceed one replica's
            # replica_max_rows capacity); ``vector_replicas`` becomes the
            # per-shard replica count and ``graph`` is unused (each shard
            # builds its own)
            self.vector_pool = ShardedVectorPool(
                pool_cfg, db, replicas_per_shard=vector_replicas,
                policy=policy, use_pallas=use_pallas, seed=seed)
        else:
            self.vector_pool = VectorPool(pool_cfg, db, graph,
                                          replicas=vector_replicas,
                                          policy=policy,
                                          use_pallas=use_pallas, seed=seed)
        self.kv_link = KVLink(bandwidth=kv_link_bw)

        self.prefill_queue: deque[GenRequest] = deque()
        self.decode_queue: deque[GenRequest] = deque()
        self.metrics = ClusterMetrics()
        self.straggler_factor = straggler_factor
        self.elastic_decode = elastic_decode
        self.max_decode_instances = n_decode * 2
        self._events: list = []
        self._eseq = itertools.count()
        self._probe_cb: Dict[int, Callable] = {}
        self._pool_cursor = 0
        self._recent_stalls: deque = deque(maxlen=256)
        self.t_now = 0.0
        self._chips = chips_per_instance
        # closed-loop SLO autoscaler (goodput control plane). None (the
        # default) schedules nothing and changes no seam — bit-identical
        # to a build without the subsystem
        self.autoscaler: Optional[Autoscaler] = None
        self._autoscale_scheduled = False
        if autoscaler is not None:
            self.metrics.set_window(autoscaler.window_s)
            self.autoscaler = Autoscaler(self, autoscaler)
        if self.vector_pool.sanitizer is not None:
            # extend the pool's invariant layer with the cluster-level
            # orphaned-probe check (no-op when sanitizer_enabled is off)
            self.vector_pool.sanitizer.attach_cluster(self)

    # ------------------------------------------------------------- events
    def schedule(self, t: float, fn: Callable):
        heapq.heappush(self._events, (max(t, self.t_now), next(self._eseq), fn))

    def run(self, until: float):
        self.schedule(self.t_now, self._poll_pool)
        if self.autoscaler is not None and not self._autoscale_scheduled:
            self._autoscale_scheduled = True
            self.schedule(self.t_now + self.autoscaler.cfg.epoch_s,
                          self._autoscale_epoch)
        while self._events and self._events[0][0] <= until:
            t, _, fn = heapq.heappop(self._events)
            self.t_now = t
            fn()
        self.t_now = until
        self.vector_pool.run_until(until)
        self._collect_pool_completions()

    # ------------------------------------------------------------ arrival
    @property
    def _cache_enabled(self) -> bool:
        return (self.pool_cfg is not None
                and self.pool_cfg.semantic_cache_enabled)

    def arrive(self, req: GenRequest):
        def _on_arrival():
            # answer-cache lookup gates the whole PD pipeline; an empty
            # cache segment is a guaranteed (and free) miss
            if self._cache_enabled and self.vector_pool.cache_size > 0:
                self._submit_probe(req, "cache_lookup",
                                   self._after_cache_lookup)
            else:
                self._start_miss_path(req)

        self.schedule(req.t_arrival, _on_arrival)

    def _start_miss_path(self, req: GenRequest):
        """The pre-cache arrival path: prefill RAG probe, then prefill."""
        if req.prefill_rag and self.pool_cfg is not None:
            self._submit_probe(req, "prefill", self._after_prefill_rag)
        else:
            self._enqueue_prefill(req)

    def _after_prefill_rag(self, req: GenRequest, vreq: VectorRequest):
        req.t_retrieval_done = self.t_now
        self._enqueue_prefill(req)

    # ----------------------------------------------------- semantic cache
    def _after_cache_lookup(self, req: GenRequest, vreq: VectorRequest):
        req.t_cache_done = self.t_now
        thr = self.vector_pool.scheduler.classes["cache_lookup"] \
            .score_threshold
        meta = None
        if vreq.result_ids is not None and vreq.result_dists is not None:
            t_fixed = (vreq.t_completed if vreq.t_completed is not None
                       else self.t_now)
            for row, dist in zip(vreq.result_ids, vreq.result_dists):
                if float(dist) <= thr:
                    # meta_at guards slot reuse: a row evicted and
                    # re-filled after this lookup completed must not serve
                    # the new occupant's answer for the old query
                    meta = self.vector_pool.meta_at(int(row), t_fixed)
                    if meta is not None:
                        break
        if meta is None:
            self._start_miss_path(req)
            return
        # hit: serve the cached answer — the entire prefill→KV→decode
        # pipeline is skipped. The answer itself is NOT free: its tokens
        # ship over the shared KV link (answer_bytes_per_token each), so a
        # hit landing while a multi-MB prefill KV transfer is in flight
        # queues behind it — TTFT = lookup round trip + transfer
        req.cache_hit = True
        req.tokens_out = int(meta["tokens"])
        self.metrics.cache_hits += 1
        self.metrics.saved_prefill_tokens += req.prompt_len
        nbytes = req.tokens_out * self.pool_cfg.answer_bytes_per_token
        t_ready = self.kv_link.transfer(self.t_now, nbytes) \
            if nbytes else self.t_now

        def _serve(r=req):
            r.t_first_token = self.t_now
            r.t_done = self.t_now
            self.metrics.record_finish(r)

        self.schedule(t_ready, _serve)

    def _finish_generation(self, req: GenRequest):
        """Completion hook: async-insert the (prompt embedding → answer)
        pair as a background-class request (cache misses only)."""
        req.t_done = self.t_now
        self.metrics.record_finish(req)
        if self._cache_enabled:
            self.vector_pool.submit_insert(
                self._prompt_embedding(req),
                meta={"tokens": req.tokens_out,
                      "prompt_id": req.prompt_id
                      if req.prompt_id is not None else req.rid},
                t_now=self.t_now)

    # ------------------------------------------------------------ prefill
    def _enqueue_prefill(self, req: GenRequest):
        self.prefill_queue.append(req)
        self._try_start_prefill()

    def _healthy(self, pool):
        # "serving" = alive and not draining/retired: a draining instance
        # finishes its in-flight work but takes no NEW admissions (both
        # flags are always False outside an autoscaler drain)
        ew = [i.health.step_ewma for i in pool if i.health.serving]
        med = np.median([e for e in ew if e > 0]) if any(e > 0 for e in ew) else 0
        out = []
        for inst in pool:
            if not inst.health.serving:
                continue
            if med and inst.health.step_ewma > self.straggler_factor * med:
                continue  # straggler: route around it
            out.append(inst)
        return out or [i for i in pool if i.health.serving]

    def _try_start_prefill(self):
        for inst in self._healthy(self.prefill_pool):
            if inst.busy_until > self.t_now or not self.prefill_queue:
                continue
            batch, tokens = [], 0
            while self.prefill_queue and tokens < inst.max_batch_tokens:
                r = self.prefill_queue[0]
                if batch and tokens + r.prompt_len > inst.max_batch_tokens:
                    break
                batch.append(self.prefill_queue.popleft())
                tokens += r.prompt_len
            if not batch:
                continue
            t_done = inst.start_batch(self.t_now, batch)
            self.schedule(t_done, lambda i=inst, b=batch: self._finish_prefill(i, b))

    def _finish_prefill(self, inst: PrefillInstance, batch: List[GenRequest]):
        inst.current = []
        if inst.health.draining:
            self._retire_instance("prefill", inst)
        for req in batch:
            req.t_prefill_done = self.t_now
            nbytes = req.prompt_len * kv_bytes_per_token(self.cfg)
            t_kv = self.kv_link.transfer(self.t_now, nbytes) \
                if nbytes else self.t_now
            self.schedule(t_kv, lambda r=req: self._kv_arrived(r))
        self._try_start_prefill()

    # ------------------------------------------------------------- decode
    def _kv_arrived(self, req: GenRequest):
        req.t_kv_arrived = self.t_now
        self.decode_queue.append(req)
        self._try_admit_decode()

    def _try_admit_decode(self):
        for inst in self._healthy(self.decode_pool):
            while self.decode_queue and inst.can_admit(self.decode_queue[0]):
                inst.admit(self.decode_queue.popleft())
            if inst.active and not inst.stepping:
                inst.stepping = True
                self.schedule(self.t_now + inst.step_time(self.t_now),
                              lambda i=inst: self._decode_step(i))
        if self.elastic_decode and len(self.decode_queue) > 4 * max(
                1, len(self.decode_pool)) and \
                len(self.decode_pool) < self.max_decode_instances:
            # audited (no fire-and-forget scaling): the ScaleEvent records
            # the queue depth that triggered this add
            self.add_decode_instance(reason="elastic_decode_queue",
                                     signal=float(len(self.decode_queue)))

    def _decode_step(self, inst: DecodeInstance):
        if not inst.health.alive:
            return
        done = []
        for req in list(inst.active.values()):
            if self.t_now < req.stalled_until:
                continue  # stalled on a RAG probe: no token this step
            req.tokens_out += 1
            inst.tokens_emitted += 1
            req.token_times.append(self.t_now)
            if req.t_first_token is None:
                req.t_first_token = self.t_now
            if req.rag_interval and req.tokens_out < req.max_new_tokens and \
                    req.tokens_out % req.rag_interval == 0:
                req.stalled_until = float("inf")
                self._submit_probe(req, "decode", self._after_decode_rag)
            if req.tokens_out >= req.max_new_tokens:
                done.append(req)
        for req in done:
            inst.release(req)
            self._finish_generation(req)
        if inst.active:
            self.schedule(self.t_now + inst.step_time(self.t_now),
                          lambda: self._decode_step(inst))
        else:
            inst.stepping = False
            if inst.health.draining:
                self._retire_instance("decode", inst)
        self._try_admit_decode()

    def _after_decode_rag(self, req: GenRequest, vreq: VectorRequest):
        stall = self.t_now - (vreq.t_arrival)
        req.stall_time += stall
        req.stalled_until = self.t_now
        self._recent_stalls.append(stall)

    # ------------------------------------------------------- vector pool
    # probe rid spaces per retrieval class: rids derive from the GENERATION
    # request identity, so probe streams (and the engine entry keys folded
    # from them) are reproducible across runs/arms even when another class
    # (cache lookups) adds or removes probes in between. Windows are sized
    # so classes can never collide with each other or with the pool's
    # insert rid space (1 << 28): base + rid·4096 + tokens_out < base + 2³²
    _PROBE_RID_BASE = {"prefill": 1 << 32, "decode": 2 << 32,
                       "cache_lookup": 3 << 32}

    def _probe_rid(self, req: GenRequest, kind: str) -> int:
        if req.rid >= (1 << 20) or req.tokens_out >= 4096:
            raise ValueError(
                f"probe rid window exceeded (rid={req.rid}, "
                f"tokens_out={req.tokens_out}); widen _PROBE_RID_BASE")
        return self._PROBE_RID_BASE[kind] + req.rid * 4096 + req.tokens_out

    def _submit_probe(self, req: GenRequest, kind: str, cb: Callable):
        rclass = self.vector_pool.scheduler.classes[kind]
        # cache lookups are issued from the request front-end, prefill-side
        rtt = (self.placement.decode_rtt if kind == "decode"
               else self.placement.prefill_rtt)
        rid = self._probe_rid(req, kind)
        ddl = self.t_now + rclass.deadline_ms / 1e3
        qvec = (self._prompt_embedding(req) if kind == "cache_lookup"
                else self._query_for(req))
        vreq = VectorRequest(rid, kind, qvec, self.t_now + rtt / 2, ddl,
                             est_extends=rclass.est_extends)
        self._probe_cb[rid] = (req, cb, rtt)
        self.vector_pool.submit(vreq)

    def _query_for(self, req: GenRequest) -> np.ndarray:
        rng = np.random.default_rng(req.rid * 7919 + req.tokens_out)
        n = self.vector_pool.db.shape[0]
        base = self.vector_pool.db[rng.integers(0, n)]
        return np.asarray(base) + rng.normal(0, 0.1, size=base.shape).astype(
            np.float32)

    def _prompt_embedding(self, req: GenRequest) -> np.ndarray:
        """Deterministic per-prompt embedding: requests sharing a
        ``prompt_id`` embed identically (repeats of one prompt), so a
        cached answer's embedding is bit-equal to its repeat lookups."""
        pid = req.prompt_id if req.prompt_id is not None else req.rid
        rng = np.random.default_rng(0xC0FFEE + pid * 7919)
        n = self.vector_pool.db.shape[0]
        base = self.vector_pool.db[rng.integers(0, n)]
        return (np.asarray(base, np.float32)
                + rng.normal(0, 0.05, size=base.shape)).astype(np.float32)

    def _poll_pool(self):
        self.vector_pool.run_until(self.t_now)
        self._collect_pool_completions()
        self._update_feedback()
        self.schedule(self.t_now + self.poll_dt, self._poll_pool)

    def _collect_pool_completions(self):
        comp = self.vector_pool.metrics.completed
        while self._pool_cursor < len(comp):
            vreq = comp[self._pool_cursor]
            self._pool_cursor += 1
            entry = self._probe_cb.pop(vreq.rid, None)
            if entry is None:
                continue
            req, cb, rtt = entry
            self.schedule(max(self.t_now, vreq.t_completed + rtt / 2),
                          lambda r=req, v=vreq, c=cb: c(r, v))

    def _update_feedback(self):
        fb = self.vector_pool.feedback
        fb.u_kv = self.kv_link.utilization(self.t_now)
        pre_waits = [v.wait for v in self.vector_pool.metrics.completed[-128:]
                     if v.kind == "prefill"]
        fb.prefill_p95_wait = percentile(pre_waits, 95) if pre_waits else 0.0
        if self._recent_stalls:
            # stall fraction proxy: stall per Δ tokens of decode time.
            # Median step EWMA over ALIVE decode instances — instance 0 may
            # be dead (kill_decode(0)) or a straggler, and its stale EWMA
            # would skew the stall fraction for the whole control loop.
            avg_stall = float(np.mean(self._recent_stalls))
            ew = [i.health.step_ewma for i in self.decode_pool
                  if i.health.alive and not i.health.retired
                  and i.health.step_ewma > 0]
            step = float(np.median(ew)) if ew else 1e-3
            delta = max(1, next((r.rag_interval for i in self.decode_pool
                                 for r in i.active.values()), 64))
            fb.decode_stall_frac = avg_stall / max(avg_stall + step * delta,
                                                   1e-9)
        # surface pool-level preemption + rebalance counters for cluster
        # summaries (per-shard p95 wait keys exist only for sharded pools)
        pm = self.vector_pool.metrics
        self.metrics.pool_preemptions = pm.preemptions
        self.metrics.pool_resumes = pm.resumes
        self.metrics.pool_rebalances = pm.rebalances
        self.metrics.pool_migrations = pm.migrated_entries
        self.metrics.pool_shard_p95_wait = {
            s: pm.shard_p95_wait(s) for s in sorted(pm.shard_waits)}
        # failure-recovery counters (chaos / high-availability serving).
        # probes_cancelled adds the pool's own count (hedge losers are
        # counted separately as hedges_wasted) to cluster-side teardowns.
        self.metrics.pool_replica_deaths = pm.replica_deaths
        self.metrics.pool_shard_losses = pm.shard_losses
        self.metrics.pool_shard_reassignments = pm.shard_reassignments
        self.metrics.pool_rescued = pm.rescued
        self.metrics.pool_retries = pm.retries
        self.metrics.pool_retries_exhausted = pm.retries_exhausted
        self.metrics.pool_hedges = pm.hedges
        self.metrics.pool_hedges_won = pm.hedges_won
        self.metrics.pool_hedges_wasted = pm.hedges_wasted
        self.metrics.probes_cancelled = pm.probes_cancelled
        self.metrics.cache_entries_recovered = pm.cache_recovered
        self.metrics.cache_entries_lost = pm.cache_lost

    # ------------------------------------------- autoscaler control plane
    def _autoscale_epoch(self):
        self.autoscaler.epoch()
        self.schedule(self.t_now + self.autoscaler.cfg.epoch_s,
                      self._autoscale_epoch)

    def gpu_units(self) -> int:
        """Instance-unit GPU accounting for the fixed autoscaler budget
        (1 unit = one prefill/decode instance or one vector replica).
        Draining instances still hold their unit until retired; dead and
        retired instances hold nothing."""
        llm = sum(1 for i in self.prefill_pool + self.decode_pool
                  if i.health.alive and not i.health.retired)
        return llm + len(self.vector_pool.replicas)

    def _scale_event(self, pool: str, delta: int, reason: str,
                     signal: float):
        self.metrics.scale_events.append(
            ScaleEvent(self.t_now, pool, delta, reason, float(signal)))

    def _retire_instance(self, pool_name: str, inst):
        """A drained instance emptied: it stops counting against the GPU
        budget (it stays in the pool list so chaos closures keep stable
        indices) and the autoscaler may re-grant the freed unit."""
        inst.health.draining = False
        inst.health.retired = True
        if self.autoscaler is not None:
            self.autoscaler.on_drain_complete(pool_name, self.t_now)

    def add_prefill_instance(self, *, reason: str = "manual",
                             signal: float = 0.0,
                             kick: bool = False) -> PrefillInstance:
        """Scale-up actuator: a fresh prefill instance with the SAME
        placement-derived capacity/contention as the initial pool."""
        pl = self.placement
        inst = PrefillInstance(
            len(self.prefill_pool), self.cfg, self._chips, hw=self.hw,
            capacity_factor=pl.llm_capacity_factor_prefill,
            contention=(pl.hbm_contention_factor
                        if pl.llm_capacity_factor_prefill < 1 else 1.0))
        self.prefill_pool.append(inst)
        self._scale_event("prefill", +1, reason, signal)
        if kick:
            self._try_start_prefill()
        return inst

    def add_decode_instance(self, *, reason: str = "manual",
                            signal: float = 0.0,
                            kick: bool = False) -> DecodeInstance:
        """Scale-up actuator (also the elastic-decode path): scaled-up
        instances get the SAME placement-derived capacity loss / HBM
        contention / EP penalty as the initial pool — colocated
        placements must not gain anomalously fast replicas."""
        pl = self.placement
        inst = DecodeInstance(
            len(self.decode_pool), self.cfg, self._chips,
            max_batch=self.decode_pool[0].max_batch, hw=self.hw,
            capacity_factor=pl.llm_capacity_factor_decode,
            contention=(pl.hbm_contention_factor
                        if pl.llm_capacity_factor_decode < 1 else 1.0),
            ep_penalty=pl.ep_dispatch_penalty)
        self.decode_pool.append(inst)
        self._scale_event("decode", +1, reason, signal)
        if kick:
            self._try_admit_decode()
        return inst

    def drain_prefill_instance(self, *, reason: str = "manual",
                               signal: float = 0.0
                               ) -> Optional[PrefillInstance]:
        """Graceful scale-down: the least-loaded serving prefill instance
        stops taking admissions, finishes its running batch, then
        retires. Refuses (None) rather than drain the last one."""
        cands = [i for i in self.prefill_pool if i.health.serving]
        if len(cands) <= 1:
            return None
        inst = min(cands, key=lambda i: (len(i.current), i.iid))
        inst.health.draining = True
        self._scale_event("prefill", -1, reason, signal)
        if not inst.current and inst.busy_until <= self.t_now:
            self._retire_instance("prefill", inst)
        return inst

    def drain_decode_instance(self, *, reason: str = "manual",
                              signal: float = 0.0
                              ) -> Optional[DecodeInstance]:
        """Graceful scale-down: the least-loaded serving decode instance
        stops admitting but keeps stepping its active requests to
        completion — device KV is per-instance, so a drain (unlike a
        kill) forces zero re-prefills and loses nothing. Refuses (None)
        rather than drain the last serving instance."""
        cands = [i for i in self.decode_pool if i.health.serving]
        if len(cands) <= 1:
            return None
        inst = min(cands, key=lambda i: (len(i.active), i.iid))
        inst.health.draining = True
        self._scale_event("decode", -1, reason, signal)
        if not inst.active:
            self._retire_instance("decode", inst)
        return inst

    def add_vector_replica(self, *, reason: str = "manual",
                           signal: float = 0.0):
        """Scale-up actuator: sharded pools spawn on the hottest shard
        (max load score — where the deficit is), monolithic pools join
        the shared index at the clock frontier."""
        pool = self.vector_pool
        if hasattr(pool, "shards"):
            t = self.t_now
            s = max(range(pool.shards.num_shards),
                    key=lambda i: (pool.shard_load_score(i, t), -i))
            pool.spawn_replica(s)
        else:
            pool.add_replica()
        self._scale_event("vector", +1, reason, signal)

    def drain_vector_replica(self, *, shard: Optional[int] = None,
                             reason: str = "manual",
                             signal: float = 0.0) -> bool:
        """Safe scale-down through the pool's checkpoint-intact drain
        (``drain_replica``): in-flight work re-queues with its progress,
        serving minimums hold. False when no replica can be drained.
        ``shard`` pins the donor shard (sharded pools; monolithic pools
        ignore it)."""
        ok = self.vector_pool.drain_replica(shard)
        if ok:
            self._scale_event("vector", -1, reason, signal)
        return ok

    # ----------------------------------------------------------- failures
    def _cancel_probes(self, req: GenRequest):
        """Tear down every in-flight vector-pool probe issued for ``req``:
        its instance died, nobody will consume the answers, and leaked
        probes burn extend budget competing against live traffic. (The
        re-prefill path re-issues what the retry actually needs.)"""
        for rid in [r for r, (g, _, _) in self._probe_cb.items() if g is req]:
            self._probe_cb.pop(rid)
            self.vector_pool.cancel(rid)

    def kill_prefill(self, idx: int):
        def _kill(inst=self.prefill_pool[idx]):
            inst.health.alive = False
            self.metrics.prefill_deaths += 1
            for req in inst.current:
                req.re_prefills += 1
                self._cancel_probes(req)
                self.prefill_queue.appendleft(req)
            inst.current = []
            if inst.health.draining:
                # a killed draining instance can never empty gracefully —
                # complete the drain now so a pending grant isn't stranded
                self._retire_instance("prefill", inst)
            self._try_start_prefill()
        return _kill

    def kill_decode(self, idx: int):
        def _kill(inst=self.decode_pool[idx]):
            inst.health.alive = False
            self.metrics.decode_deaths += 1
            for req in list(inst.active.values()):
                inst.release(req)
                req.re_prefills += 1
                req.stalled_until = 0.0
                self._cancel_probes(req)
                self.prefill_queue.append(req)  # device KV lost: re-prefill
            if inst.health.draining:
                self._retire_instance("decode", inst)
            self._try_start_prefill()
        return _kill

    def revive_prefill(self, idx: int):
        """Bring a killed prefill instance back (chaos downtime expiry)."""
        def _revive(inst=self.prefill_pool[idx]):
            inst.health.alive = True
            self._try_start_prefill()
        return _revive

    def revive_decode(self, idx: int):
        """Bring a killed decode instance back (chaos downtime expiry)."""
        def _revive(inst=self.decode_pool[idx]):
            inst.health.alive = True
            self._try_admit_decode()
        return _revive

    def set_decode_slowdown(self, idx: int, factor: float):
        def _slow(inst=self.decode_pool[idx]):
            inst.health.slowdown = factor
        return _slow

    def set_kv_bandwidth(self, factor: float):
        """Scale the prefill→decode KV link bandwidth by ``factor``
        (transient link degradation; factor > 1 restores)."""
        def _set():
            self.kv_link.bandwidth *= factor
        return _set


def make_sharded_pool_sim(model_cfg=None, *, num_vectors: int = 6000,
                          dim: int = 64, num_shards: int = 4,
                          replica_max_rows: int = 2600,
                          nprobe_shards: int = 0, seed: int = 11,
                          pool_overrides: Optional[dict] = None,
                          **cluster_kw):
    """The ``sharded_pool`` scenario: a ClusterSim whose retrieval corpus is
    deliberately sized PAST one replica's modeled HBM capacity
    (``replica_max_rows < num_vectors``) — a monolithic ``VectorPool``
    over it raises ``CapacityError``; the sharded scatter–gather pool
    serves it with per-shard inserts and zero global broadcasts.

    Returns (sim, db, queries). ``model_cfg=None`` uses the
    phi3-medium-14b smoke config.
    """
    import dataclasses as _dc

    from repro.configs import get_smoke_config
    from repro.configs.base import VectorPoolConfig
    from repro.vector.dataset import make_dataset

    assert replica_max_rows < num_vectors, \
        "the scenario exists to exceed one replica's capacity"
    if model_cfg is None:
        model_cfg = get_smoke_config("phi3-medium-14b")
    pool_cfg = VectorPoolConfig(
        num_vectors=num_vectors, dim=dim, graph_degree=16, max_requests=16,
        top_m=32, parents_per_step=2, task_batch=2048, visited_slots=512,
        top_k=10, semantic_cache_enabled=True, cache_capacity=128,
        num_shards=num_shards, nprobe_shards=nprobe_shards,
        replica_max_rows=replica_max_rows)
    if pool_overrides:
        pool_cfg = _dc.replace(pool_cfg, **pool_overrides)
    db, queries = make_dataset(num_vectors, dim, num_clusters=32,
                               num_queries=256, seed=seed)
    defaults = dict(placement="disaggregated", policy="trinity",
                    n_prefill=2, n_decode=2, decode_batch=8, seed=seed)
    defaults.update(cluster_kw)
    sim = ClusterSim(model_cfg, pool_cfg, db, None, **defaults)
    return sim, db, queries
