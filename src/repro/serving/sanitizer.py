"""Runtime invariant sanitizer for the simulated serving stack.

The static analyzer (``tools/analyzer``) catches hazards visible in the
AST; this module is its dynamic counterpart for the invariants only an
executing pool can witness. It wraps the seams of a live
:class:`~repro.core.trinity_pool.VectorPool` /
``ShardedVectorPool`` (and optionally a
:class:`~repro.serving.cluster.ClusterSim`) with record-only checks:

``clock``       per-replica clock monotonicity — a replica's sim clock
                never moves backwards across engine steps.
``completion``  exactly-once completion per rid — no request (parent,
                probe or insert) ever lands in ``metrics.completed``
                twice.
``checkpoint``  checkpoint conservation across moves/rescues — a
                planned ``_move_replica`` re-queues every donor child
                checkpoint-intact, and a ``kill_replica`` rescue
                re-queues with the snapshot attached; nothing in flight
                is silently dropped.
``gid``         cache gid uniqueness across eviction + migration — the
                sharded index's ``_gid_loc`` and per-shard
                ``_global_of`` maps stay exact inverses, every live
                cache gid lives on exactly one shard.
``probe``       no orphaned probes after kills — every callback the
                cluster still holds in ``_probe_cb`` references a
                request that is still live inside the pool.
``replica``     replica-count conservation across scaling actions — a
                ``drain_replica`` changes the count by exactly −1 (or 0
                when refused) and never lands any shard below its
                serving floor; a spawn changes it by exactly +1; and a
                drain re-queues every donor in-flight request
                checkpoint-intact (the autoscaler's scale-down must be
                invisible to request outcomes).

Knobs-off-free: the sanitizer only exists when
``VectorPoolConfig.sanitizer_enabled`` is set. With the knob off
nothing is wrapped, no check runs, and pool behavior is bit-identical
to a build without this module.

Violations are *recorded*, never raised mid-sim — a chaos arm must keep
running so the run reports every violation, and the clean case asserts
``assert_clean()`` at the end.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Set, Tuple

__all__ = ["Violation", "PoolSanitizer", "attach"]


@dataclasses.dataclass(frozen=True)
class Violation:
    kind: str  # clock | completion | checkpoint | gid | probe | replica
    detail: str

    def __str__(self) -> str:
        return f"[{self.kind}] {self.detail}"


def attach(pool) -> "PoolSanitizer":
    """Wrap ``pool``'s seams and return the attached sanitizer."""
    return PoolSanitizer(pool)


class PoolSanitizer:
    """Record-only invariant checks wrapped around one pool instance."""

    def __init__(self, pool):
        self.pool = pool
        self.violations: List[Violation] = []
        # id(rep) → (rep, high-water clock). Holding the replica ref
        # keeps ids stable (a gc'd dead replica could otherwise recycle
        # its id onto a fresh one and inherit its high-water mark).
        self._clock_high: Dict[int, Tuple[object, float]] = {}
        self._completed_rids: Set[int] = set()
        self._completed_cursor = 0
        self._wrap_pool()

    # ------------------------------------------------------------ helpers
    def _violate(self, kind: str, detail: str):
        self.violations.append(Violation(kind, detail))

    def assert_clean(self):
        if self.violations:
            lines = "\n".join(str(v) for v in self.violations[:20])
            raise AssertionError(
                f"sanitizer recorded {len(self.violations)} violation(s):\n"
                f"{lines}")

    def report(self) -> List[str]:
        return [str(v) for v in self.violations]

    # ------------------------------------------------------- seam wiring
    def _wrap_pool(self):
        pool = self.pool
        self._wrap(pool, "_step_replica", self._around_step)
        if hasattr(pool, "_step_group"):
            # megabatched cohort stepping (ShardedVectorPool, PR 8):
            # the clock/completion checks land per cohort member
            self._wrap(pool, "_step_group", self._around_step_group)
        self._wrap(pool, "kill_replica", self._around_kill)
        self._wrap(pool, "run_until", self._around_run_until)
        if hasattr(pool, "drain_replica"):
            self._wrap(pool, "drain_replica", self._around_drain)
        for name in ("add_replica", "spawn_replica"):
            if hasattr(pool, name):
                self._wrap(pool, name, self._around_spawn)
        if hasattr(pool, "_move_replica"):
            self._wrap(pool, "_move_replica", self._around_move)
        if hasattr(pool, "shards"):
            for name in ("insert_local", "migrate_entries",
                         "drop_shard_cache", "restore_entries"):
                self._wrap(pool.shards, name, self._around_index_mutation)

    @staticmethod
    def _wrap(obj, name: str, around: Callable):
        inner = getattr(obj, name)

        def wrapped(*args, __inner=inner, __around=around, **kwargs):
            return __around(__inner, *args, **kwargs)

        setattr(obj, name, wrapped)

    # ----------------------------------------------------------- checks
    def _around_step(self, inner, rep, t_end):
        before = rep.clock
        out = inner(rep, t_end)
        _, high = self._clock_high.get(id(rep), (rep, before))
        high = max(high, before)
        if rep.clock < high - 1e-12:
            self._violate(
                "clock",
                f"replica rid={rep.rid} clock moved backwards: "
                f"{high:.9f} -> {rep.clock:.9f}")
        self._clock_high[id(rep)] = (rep, max(high, rep.clock))
        self._scan_completions()
        return out

    def _around_step_group(self, inner, cohort, t_end):
        before = [(rep, rep.clock) for rep in cohort]
        out = inner(cohort, t_end)
        for rep, b in before:
            _, high = self._clock_high.get(id(rep), (rep, b))
            high = max(high, b)
            if rep.clock < high - 1e-12:
                self._violate(
                    "clock",
                    f"replica rid={rep.rid} clock moved backwards in a "
                    f"grouped step: {high:.9f} -> {rep.clock:.9f}")
            self._clock_high[id(rep)] = (rep, max(high, rep.clock))
        self._scan_completions()
        return out

    def _scan_completions(self):
        comp = self.pool.metrics.completed
        while self._completed_cursor < len(comp):
            req = comp[self._completed_cursor]
            self._completed_cursor += 1
            if req.rid in self._completed_rids:
                self._violate(
                    "completion",
                    f"rid={req.rid} kind={req.kind} completed twice")
            self._completed_rids.add(req.rid)
            if req.t_completed is None:
                self._violate(
                    "completion",
                    f"rid={req.rid} landed in metrics.completed without "
                    "a completion time")

    # --- kill: nothing in flight on the victim is silently dropped ------
    def _around_kill(self, inner, idx):
        pool = self.pool
        victim = pool.replicas[idx]
        in_flight = dict(victim.in_flight)
        snapshots = dict(victim.snapshots)
        rescue = bool(getattr(pool.cfg, "rescue_enabled", False))
        out = inner(idx)
        self._scan_completions()
        queued = self._queued_rids()
        pending = {r.rid for _, _, r in pool._pending}
        for rid, req in in_flight.items():
            if rid in queued or rid in pending:
                if rescue and snapshots.get(rid) is not None \
                        and req.checkpoint is None:
                    self._violate(
                        "checkpoint",
                        f"rid={rid} had a rescue snapshot but re-queued "
                        "with no checkpoint attached")
                continue
            if self._resolved_elsewhere(req):
                continue
            self._violate(
                "checkpoint",
                f"rid={rid} kind={req.kind} was in flight on killed "
                f"replica rid={victim.rid} and is nowhere afterwards "
                "(not queued, not pending, not completed)")
        self._check_gids()
        return out

    def _resolved_elsewhere(self, req) -> bool:
        """A victim's in-flight request that is neither queued nor
        pending must have completed — as itself, or (sharded children)
        through its parent's fan-out resolving without it."""
        if req.t_completed is not None or req.rid in self._completed_rids:
            return True
        parent_rid = getattr(req, "parent_rid", None)
        if parent_rid is None:
            return False
        fan = getattr(self.pool, "_fanout", {}).get(parent_rid)
        if fan is None:
            # parent already finalized (or cancelled) — the child's
            # obligation is discharged
            return True
        # hedge pair: the twin still owns the shard
        return req.shard not in fan.pending

    def _queued_rids(self) -> Set[int]:
        pool = self.pool
        scheds = getattr(pool, "schedulers", None) or [pool.scheduler]
        out: Set[int] = set()
        for sched in scheds:
            for req in sched.queued_requests():
                out.add(req.rid)
        return out

    # --- planned move: conservation, checkpoint-intact ------------------
    def _around_move(self, inner, src, dst, t, exclude=None):
        pool = self.pool
        before_flight: Dict[int, object] = {}
        for rep in pool.shard_replicas(src):
            if rep is not exclude:
                before_flight.update(rep.in_flight)
        before_queued = self._queued_rids()
        out = inner(src, dst, t, exclude=exclude)
        after_queued = self._queued_rids()
        after_flight: Set[int] = set()
        for rep in pool.replicas:
            after_flight.update(rep.in_flight)
        for rid, req in before_flight.items():
            if rid in after_flight:
                continue  # stayed on a non-donor replica
            if rid not in after_queued:
                self._violate(
                    "checkpoint",
                    f"rid={rid} was in flight on shard {src} before a "
                    "planned move and is neither in flight nor queued "
                    "afterwards")
            elif rid not in before_queued and req.checkpoint is None:
                self._violate(
                    "checkpoint",
                    f"rid={rid} re-queued by a planned move WITHOUT its "
                    "checkpoint — moves must preserve progress")
        self._check_gids()
        return out

    # --- scaling actions: replica-count conservation --------------------
    def _around_drain(self, inner, *args, **kwargs):
        """A drain removes EXACTLY one replica (or none, when refused),
        never breaches a serving floor, and every request that was in
        flight on the donor is re-queued checkpoint-intact (or pending /
        already completed) — an autoscaler scale-down must be invisible
        to request outcomes."""
        pool = self.pool
        n_before = len(pool.replicas)
        before_flight: Dict[int, object] = {}
        for rep in pool.replicas:
            before_flight.update(rep.in_flight)
        before_queued = self._queued_rids()
        out = inner(*args, **kwargs)
        self._scan_completions()
        n_after = len(pool.replicas)
        delta = n_after - n_before
        if delta != (-1 if out else 0):
            self._violate(
                "replica",
                f"drain_replica returned {out!r} but replica count moved "
                f"{n_before} -> {n_after}")
        if out:
            self._check_floors()
            after_queued = self._queued_rids()
            pending = {r.rid for _, _, r in pool._pending}
            after_flight: Set[int] = set()
            for rep in pool.replicas:
                after_flight.update(rep.in_flight)
            for rid, req in before_flight.items():
                if rid in after_flight:
                    continue  # survived on a non-donor replica
                if rid not in after_queued and rid not in pending \
                        and not self._resolved_elsewhere(req):
                    self._violate(
                        "replica",
                        f"rid={rid} kind={req.kind} was in flight before "
                        "a drain and is nowhere afterwards (not queued, "
                        "not pending, not completed)")
                elif rid in after_queued and rid not in before_queued \
                        and req.checkpoint is None:
                    self._violate(
                        "replica",
                        f"rid={rid} re-queued by a drain WITHOUT its "
                        "checkpoint — drains must preserve progress")
        self._check_gids()
        return out

    def _around_spawn(self, inner, *args, **kwargs):
        pool = self.pool
        n_before = len(pool.replicas)
        out = inner(*args, **kwargs)
        n_after = len(pool.replicas)
        if n_after != n_before + 1:
            self._violate(
                "replica",
                f"spawn moved replica count {n_before} -> {n_after} "
                "(want exactly +1)")
        return out

    def _check_floors(self):
        pool = self.pool
        if hasattr(pool, "shards"):
            for s in range(pool.shards.num_shards):
                n = len(pool.shard_replicas(s))
                if n < pool.shard_floor(s):
                    self._violate(
                        "replica",
                        f"shard {s} at {n} replicas, below its serving "
                        f"floor {pool.shard_floor(s)}")
        elif len(pool.replicas) < pool.drain_floor():
            self._violate(
                "replica",
                f"pool at {len(pool.replicas)} replicas, below its "
                f"serving floor {pool.drain_floor()}")

    # --- cache gid uniqueness -------------------------------------------
    def _around_index_mutation(self, inner, *args, **kwargs):
        out = inner(*args, **kwargs)
        self._check_gids()
        return out

    def _check_gids(self):
        shards = getattr(self.pool, "shards", None)
        if shards is None:
            return
        seen: Dict[int, Tuple[int, int]] = {}
        for s, gmap in enumerate(shards._global_of):
            for local, gid in enumerate(gmap):
                gid = int(gid)
                if gid < shards.n:
                    continue  # tombstone (-1) or frozen corpus row
                if gid in seen:
                    self._violate(
                        "gid",
                        f"cache gid {gid} live on two locations: "
                        f"{seen[gid]} and {(s, local)}")
                    continue
                seen[gid] = (s, local)
                if shards._gid_loc.get(gid) != (s, local):
                    self._violate(
                        "gid",
                        f"cache gid {gid} at {(s, local)} but _gid_loc "
                        f"says {shards._gid_loc.get(gid)}")
        for gid, loc in shards._gid_loc.items():
            if seen.get(gid) != loc:
                self._violate(
                    "gid",
                    f"_gid_loc maps gid {gid} to {loc} but the shard map "
                    f"holds {seen.get(gid)}")
        for gid in seen:
            if gid >= shards._next_cache_gid:
                self._violate(
                    "gid",
                    f"live cache gid {gid} >= next allocation counter "
                    f"{shards._next_cache_gid} (id reuse ahead)")

    def _around_run_until(self, inner, t_end):
        out = inner(t_end)
        self._scan_completions()
        self._check_gids()
        self._check_cache_meta()
        return out

    def _check_cache_meta(self):
        """At a quiescent point (end of ``run_until``) every answer-cache
        payload must reference a live gid — metadata for an evicted or
        lost entry is a stale-serving hazard."""
        shards = getattr(self.pool, "shards", None)
        if shards is None:
            return
        backup = getattr(self.pool, "_cache_backup", {})
        for gid in self.pool.cache_meta:
            if gid not in shards._gid_loc and gid not in backup:
                self._violate(
                    "gid",
                    f"cache_meta holds payload for gid {gid} which is "
                    "neither live on any shard nor host-backed")

    # ------------------------------------------------ cluster-level hook
    def attach_cluster(self, sim):
        """Additionally wrap a :class:`ClusterSim` that owns this pool:
        after every completion sweep, each callback still registered in
        ``_probe_cb`` must reference a probe that is live inside the
        pool — an entry whose probe vanished (killed instance whose
        teardown missed it) would wait forever."""
        self._wrap(sim, "_collect_pool_completions",
                   lambda inner: self._after_collect(inner, sim))

    def _after_collect(self, inner, sim):
        out = inner()
        live = self._live_probe_rids()
        for rid in sim._probe_cb:
            if rid not in live:
                self._violate(
                    "probe",
                    f"orphaned probe callback: rid={rid} is registered "
                    "in _probe_cb but no live pool request carries it")
        return out

    def _live_probe_rids(self) -> Set[int]:
        pool = self.pool
        live = {r.rid for _, _, r in pool._pending}
        live |= self._queued_rids()
        if hasattr(pool, "_fanout"):
            live |= set(pool._fanout.keys())
        for rep in pool.replicas:
            live |= set(rep.in_flight.keys())
        # completions scanned this sweep have already had their
        # callbacks popped; anything still completing this instant is
        # in metrics.completed and no longer in _probe_cb
        live |= self._completed_rids
        live |= {r.rid for r in pool.metrics.completed}
        return live
