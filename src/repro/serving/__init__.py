"""PD-disaggregated serving runtime: paged KV, prefill/decode engines, the
Mooncake-style KV transfer link, and the event-driven cluster simulator."""
