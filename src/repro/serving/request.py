"""Generation request lifecycle + SLO accounting."""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Dict, List, Optional

import numpy as np


@dataclasses.dataclass
class GenRequest:
    rid: int
    prompt_len: int
    max_new_tokens: int
    t_arrival: float
    rag_interval: int = 0  # Δ: decode RAG probe every Δ tokens (0 = off)
    prefill_rag: bool = True
    # semantic answer cache: requests sharing a prompt_id are repeats of
    # the same prompt (identical embedding); None => unique (rid)
    prompt_id: Optional[int] = None
    cache_hit: bool = False  # served from the answer cache (no PD pipeline)
    # lifecycle timestamps
    t_cache_done: Optional[float] = None  # answer-cache lookup returned
    t_retrieval_done: Optional[float] = None
    t_prefill_start: Optional[float] = None
    t_prefill_done: Optional[float] = None
    t_kv_arrived: Optional[float] = None
    t_first_token: Optional[float] = None
    t_done: Optional[float] = None
    tokens_out: int = 0
    token_times: List[float] = dataclasses.field(default_factory=list)
    stall_time: float = 0.0  # decode time spent waiting on RAG
    stalled_until: float = 0.0
    re_prefills: int = 0  # failure recoveries

    @property
    def ttft(self) -> Optional[float]:
        if self.t_first_token is None:
            return None
        return self.t_first_token - self.t_arrival

    @property
    def tpot(self) -> Optional[float]:
        if len(self.token_times) < 2:
            return None
        ts = np.diff(np.asarray(self.token_times))
        return float(np.mean(ts))


def percentile(xs, q):
    xs = [x for x in xs if x is not None]
    return float(np.percentile(np.asarray(xs), q)) if xs else 0.0


def slo_good(req: GenRequest, ttft_slo_s: float, tpot_slo_s: float) -> bool:
    """Did this finished request land inside the SLO? Goodput counts only
    these (DistServe framing): TTFT within budget AND — when the request
    actually decoded — TPOT within budget. Cache hits carry no TPOT and
    are judged on TTFT alone."""
    if req.ttft is None or req.ttft > ttft_slo_s:
        return False
    tpot = req.tpot
    return tpot is None or tpot <= tpot_slo_s


class RollingWindow:
    """Incremental time-ordered sample window.

    Samples arrive in nondecreasing sim time via :meth:`add`; accessors
    prune anything older than ``window_s`` behind ``t_now`` and answer
    percentiles/rates over what remains — O(1) amortized per sample, so
    a controller can read it every epoch instead of re-scanning the full
    run. ``window_s <= 0`` keeps every sample (full-run mode), which is
    how the end-of-run ``summary()`` and the windowed accessors share
    one code path (and one ``percentile`` definition)."""

    def __init__(self, window_s: float = 0.0):
        self.window_s = window_s
        self._samples: deque = deque()  # (t, value), t nondecreasing

    def add(self, t: float, value):
        self._samples.append((t, value))

    def _prune(self, t_now: float):
        if self.window_s <= 0:
            return
        lo = t_now - self.window_s
        while self._samples and self._samples[0][0] < lo:
            self._samples.popleft()

    def values(self, t_now: float) -> list:
        self._prune(t_now)
        return [v for _, v in self._samples]

    def count(self, t_now: float) -> int:
        self._prune(t_now)
        return len(self._samples)

    def rate(self, t_now: float) -> float:
        """Samples per second over the window (full-run mode: over the
        span from the first sample to ``t_now``)."""
        n = self.count(t_now)
        if self.window_s > 0:
            return n / self.window_s
        if not self._samples:
            return 0.0
        return n / max(t_now - self._samples[0][0], 1e-9)

    def percentile(self, q: float, t_now: float) -> float:
        return percentile(self.values(t_now), q)

    def mean(self, t_now: float) -> float:
        xs = [v for v in self.values(t_now) if v is not None]
        return float(np.mean(xs)) if xs else 0.0


@dataclasses.dataclass(frozen=True)
class ScaleEvent:
    """One audited scaling decision: every replica/instance the cluster
    adds or drains records when, which pool, which direction and the
    signal that triggered it — fire-and-forget scale-ups are banned."""

    t: float
    pool: str  # "prefill" | "decode" | "vector"
    delta: int  # +1 (add) | -1 (drain initiated)
    reason: str  # triggering signal name, e.g. "decode_queue_depth"
    signal: float = 0.0  # the signal's value at decision time


@dataclasses.dataclass
class ClusterMetrics:
    finished: List[GenRequest] = dataclasses.field(default_factory=list)
    # rolling-window horizon for the incremental accessors below (sim
    # seconds); reconfigure via set_window() BEFORE the run starts
    window_s: float = 0.25
    # audited scaling decisions (elastic decode + autoscaler actuators)
    scale_events: List[ScaleEvent] = dataclasses.field(default_factory=list)
    # vector-pool stage-aware preemption (stamped by ClusterSim)
    pool_preemptions: int = 0
    pool_resumes: int = 0
    # semantic answer cache
    cache_hits: int = 0
    saved_prefill_tokens: int = 0  # prompt tokens never prefilled (hits)
    # workload-adaptive shard rebalancing (stamped by ClusterSim; all zero
    # for monolithic pools or with rebalance_enabled=False)
    pool_rebalances: int = 0  # replicas moved cold shard → hot shard
    pool_migrations: int = 0  # cache entries re-homed between shards
    pool_shard_p95_wait: Dict[int, float] = dataclasses.field(
        default_factory=dict)  # per-shard recent child wait p95
    # failure injection / high-availability serving (stamped by ClusterSim)
    prefill_deaths: int = 0  # prefill instances fail-stopped
    decode_deaths: int = 0  # decode instances fail-stopped
    probes_cancelled: int = 0  # orphaned pool probes torn down on death
    pool_replica_deaths: int = 0
    pool_shard_losses: int = 0  # whole cache-holding shards lost
    pool_shard_reassignments: int = 0  # orphaned shards re-homed
    pool_rescued: int = 0  # in-flight probes resumed from snapshots
    pool_retries: int = 0  # probes restarted from scratch after a death
    pool_retries_exhausted: int = 0  # probes that hit the retry cap
    pool_hedges: int = 0  # duplicate twins dispatched
    pool_hedges_won: int = 0  # twins that beat the original
    pool_hedges_wasted: int = 0  # losing copies cancelled/dropped
    cache_entries_recovered: int = 0  # re-homed from backup on shard loss
    cache_entries_lost: int = 0  # unrecoverable (no backup copy)

    def __post_init__(self):
        self._make_windows()

    def _make_windows(self):
        self._w_ttft = RollingWindow(self.window_s)
        self._w_tpot = RollingWindow(self.window_s)
        self._w_done = RollingWindow(self.window_s)  # holds GenRequest refs

    def set_window(self, window_s: float):
        """Reconfigure the rolling horizon (drops buffered samples —
        call before the run starts)."""
        self.window_s = window_s
        self._make_windows()

    def record_finish(self, req: GenRequest):
        """The single completion seam: appends to ``finished`` AND feeds
        the incremental windows, so the controller's rolling view and
        the end-of-run ``summary()`` see the same stream."""
        self.finished.append(req)
        t = req.t_done if req.t_done is not None else req.t_arrival
        if req.ttft is not None:
            self._w_ttft.add(t, req.ttft)
        tpot = req.tpot
        if tpot is not None:
            self._w_tpot.add(t, tpot)
        self._w_done.add(t, req)

    # ---- incremental rolling-window accessors (controller-facing) ----
    def window_ttft_p(self, q: float, t_now: float) -> float:
        return self._w_ttft.percentile(q, t_now)

    def window_tpot_p(self, q: float, t_now: float) -> float:
        return self._w_tpot.percentile(q, t_now)

    def window_finish_rate(self, t_now: float) -> float:
        """Completions per second over the window."""
        return self._w_done.rate(t_now)

    def window_goodput(self, t_now: float, ttft_slo_s: float,
                       tpot_slo_s: float) -> float:
        """SLO-good completions per second over the window."""
        reqs = self._w_done.values(t_now)
        good = sum(1 for r in reqs if slo_good(r, ttft_slo_s, tpot_slo_s))
        if self._w_done.window_s > 0:
            return good / self._w_done.window_s
        if not reqs:
            return 0.0
        return good / max(t_now - self._w_done._samples[0][0], 1e-9)

    def goodput(self, t_elapsed: float, ttft_slo_s: float,
                tpot_slo_s: float, gpu_units: int = 1) -> float:
        """Full-run goodput per GPU-second: SLO-good completions /
        (gpu_units × t_elapsed) — the bench's cross-arm objective."""
        good = sum(1 for r in self.finished
                   if slo_good(r, ttft_slo_s, tpot_slo_s))
        return good / max(gpu_units * t_elapsed, 1e-9)

    # full-run percentile accessors: same ``percentile`` primitive as the
    # windowed path (window vs full-run agreement is tested)
    def ttft_p(self, q: float) -> float:
        return percentile([r.ttft for r in self.finished], q)

    def tpot_p(self, q: float) -> float:
        return percentile([r.tpot for r in self.finished], q)

    def summary(self, t_elapsed: float) -> dict:
        fin = self.finished
        toks = sum(r.tokens_out for r in fin)
        # only requests that actually decoded contribute decode time: a
        # request may carry t_done without t_first_token (cache hits served
        # without a decode pass, failure edge cases) and (t_done or 0) −
        # (t_first_token or 0) would go negative and skew decode_stall_frac
        decode_time = sum(r.t_done - r.t_first_token for r in fin
                          if r.t_done is not None
                          and r.t_first_token is not None)
        stall = sum(r.stall_time for r in fin)
        return {
            "requests": len(fin),
            "throughput_tok_s": toks / max(t_elapsed, 1e-9),
            "ttft_p50": self.ttft_p(50),
            "ttft_p95": self.ttft_p(95),
            "tpot_p50": self.tpot_p(50),
            "tpot_p95": self.tpot_p(95),
            "decode_stall_frac": stall / max(decode_time, 1e-9),
            "re_prefills": sum(r.re_prefills for r in fin),
            "prefill_deaths": self.prefill_deaths,
            "decode_deaths": self.decode_deaths,
            "probes_cancelled": self.probes_cancelled,
            "pool_replica_deaths": self.pool_replica_deaths,
            "pool_shard_losses": self.pool_shard_losses,
            "pool_shard_reassignments": self.pool_shard_reassignments,
            "pool_rescued": self.pool_rescued,
            "pool_retries": self.pool_retries,
            "pool_retries_exhausted": self.pool_retries_exhausted,
            "pool_hedges": self.pool_hedges,
            "pool_hedges_won": self.pool_hedges_won,
            "pool_hedges_wasted": self.pool_hedges_wasted,
            "cache_entries_recovered": self.cache_entries_recovered,
            "cache_entries_lost": self.cache_entries_lost,
            "pool_preemptions": self.pool_preemptions,
            "pool_resumes": self.pool_resumes,
            "pool_rebalances": self.pool_rebalances,
            "pool_migrations": self.pool_migrations,
            "pool_shard_p95_wait": dict(self.pool_shard_p95_wait),
            "cache_hits": self.cache_hits,
            "cache_hit_rate": self.cache_hits / max(len(fin), 1),
            "saved_prefill_tokens": self.saved_prefill_tokens,
            "scale_events": len(self.scale_events),
            "scale_ups": sum(1 for e in self.scale_events if e.delta > 0),
            "scale_downs": sum(1 for e in self.scale_events if e.delta < 0),
        }
