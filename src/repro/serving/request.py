"""Generation request lifecycle + SLO accounting."""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np


@dataclasses.dataclass
class GenRequest:
    rid: int
    prompt_len: int
    max_new_tokens: int
    t_arrival: float
    rag_interval: int = 0  # Δ: decode RAG probe every Δ tokens (0 = off)
    prefill_rag: bool = True
    # semantic answer cache: requests sharing a prompt_id are repeats of
    # the same prompt (identical embedding); None => unique (rid)
    prompt_id: Optional[int] = None
    cache_hit: bool = False  # served from the answer cache (no PD pipeline)
    # lifecycle timestamps
    t_cache_done: Optional[float] = None  # answer-cache lookup returned
    t_retrieval_done: Optional[float] = None
    t_prefill_start: Optional[float] = None
    t_prefill_done: Optional[float] = None
    t_kv_arrived: Optional[float] = None
    t_first_token: Optional[float] = None
    t_done: Optional[float] = None
    tokens_out: int = 0
    token_times: List[float] = dataclasses.field(default_factory=list)
    stall_time: float = 0.0  # decode time spent waiting on RAG
    stalled_until: float = 0.0
    re_prefills: int = 0  # failure recoveries

    @property
    def ttft(self) -> Optional[float]:
        if self.t_first_token is None:
            return None
        return self.t_first_token - self.t_arrival

    @property
    def tpot(self) -> Optional[float]:
        if len(self.token_times) < 2:
            return None
        ts = np.diff(np.asarray(self.token_times))
        return float(np.mean(ts))


def percentile(xs, q):
    xs = [x for x in xs if x is not None]
    return float(np.percentile(np.asarray(xs), q)) if xs else 0.0


@dataclasses.dataclass
class ClusterMetrics:
    finished: List[GenRequest] = dataclasses.field(default_factory=list)
    # vector-pool stage-aware preemption (stamped by ClusterSim)
    pool_preemptions: int = 0
    pool_resumes: int = 0
    # semantic answer cache
    cache_hits: int = 0
    saved_prefill_tokens: int = 0  # prompt tokens never prefilled (hits)
    # workload-adaptive shard rebalancing (stamped by ClusterSim; all zero
    # for monolithic pools or with rebalance_enabled=False)
    pool_rebalances: int = 0  # replicas moved cold shard → hot shard
    pool_migrations: int = 0  # cache entries re-homed between shards
    pool_shard_p95_wait: Dict[int, float] = dataclasses.field(
        default_factory=dict)  # per-shard recent child wait p95
    # failure injection / high-availability serving (stamped by ClusterSim)
    prefill_deaths: int = 0  # prefill instances fail-stopped
    decode_deaths: int = 0  # decode instances fail-stopped
    probes_cancelled: int = 0  # orphaned pool probes torn down on death
    pool_replica_deaths: int = 0
    pool_shard_losses: int = 0  # whole cache-holding shards lost
    pool_shard_reassignments: int = 0  # orphaned shards re-homed
    pool_rescued: int = 0  # in-flight probes resumed from snapshots
    pool_retries: int = 0  # probes restarted from scratch after a death
    pool_retries_exhausted: int = 0  # probes that hit the retry cap
    pool_hedges: int = 0  # duplicate twins dispatched
    pool_hedges_won: int = 0  # twins that beat the original
    pool_hedges_wasted: int = 0  # losing copies cancelled/dropped
    cache_entries_recovered: int = 0  # re-homed from backup on shard loss
    cache_entries_lost: int = 0  # unrecoverable (no backup copy)

    def summary(self, t_elapsed: float) -> dict:
        fin = self.finished
        toks = sum(r.tokens_out for r in fin)
        # only requests that actually decoded contribute decode time: a
        # request may carry t_done without t_first_token (cache hits served
        # without a decode pass, failure edge cases) and (t_done or 0) −
        # (t_first_token or 0) would go negative and skew decode_stall_frac
        decode_time = sum(r.t_done - r.t_first_token for r in fin
                          if r.t_done is not None
                          and r.t_first_token is not None)
        stall = sum(r.stall_time for r in fin)
        return {
            "requests": len(fin),
            "throughput_tok_s": toks / max(t_elapsed, 1e-9),
            "ttft_p50": percentile([r.ttft for r in fin], 50),
            "ttft_p95": percentile([r.ttft for r in fin], 95),
            "tpot_p50": percentile([r.tpot for r in fin], 50),
            "tpot_p95": percentile([r.tpot for r in fin], 95),
            "decode_stall_frac": stall / max(decode_time, 1e-9),
            "re_prefills": sum(r.re_prefills for r in fin),
            "prefill_deaths": self.prefill_deaths,
            "decode_deaths": self.decode_deaths,
            "probes_cancelled": self.probes_cancelled,
            "pool_replica_deaths": self.pool_replica_deaths,
            "pool_shard_losses": self.pool_shard_losses,
            "pool_shard_reassignments": self.pool_shard_reassignments,
            "pool_rescued": self.pool_rescued,
            "pool_retries": self.pool_retries,
            "pool_retries_exhausted": self.pool_retries_exhausted,
            "pool_hedges": self.pool_hedges,
            "pool_hedges_won": self.pool_hedges_won,
            "pool_hedges_wasted": self.pool_hedges_wasted,
            "cache_entries_recovered": self.cache_entries_recovered,
            "cache_entries_lost": self.cache_entries_lost,
            "pool_preemptions": self.pool_preemptions,
            "pool_resumes": self.pool_resumes,
            "pool_rebalances": self.pool_rebalances,
            "pool_migrations": self.pool_migrations,
            "pool_shard_p95_wait": dict(self.pool_shard_p95_wait),
            "cache_hits": self.cache_hits,
            "cache_hit_rate": self.cache_hits / max(len(fin), 1),
            "saved_prefill_tokens": self.saved_prefill_tokens,
        }
