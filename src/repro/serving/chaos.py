"""Deterministic fault injection for the vector pool and the cluster sim.

A chaos run is fully described by a ``(seed, schedule)`` pair: the
schedule is a sorted list of :class:`FaultEvent` drawn from per-kind
Poisson processes (``make_schedule``), and every state-dependent choice
the injector makes at fire time (which replica to straggle, which shard
to lose) comes either from pool/cluster state — itself deterministic —
or from a generator seeded by the injector seed. Re-running the same
pair against the same workload replays the exact failure sequence,
which is what makes the regression tests and the degradation-frontier
benchmark possible.

Two drive modes:

- ``run_pool(pool, t_end)`` — standalone ``VectorPool`` /
  ``ShardedVectorPool``: the injector owns the clock, interleaving
  ``pool.run_until`` with fault applications and their follow-ups
  (straggler restore, replacement-replica spawn after downtime).
- ``arm(sim)`` — a :class:`ClusterSim`: every event (and follow-up) is
  registered on the sim's own event heap; the sim clock drives firing.

Fault kinds
-----------
``kill_replica``      fail-stop the busiest pool replica (in-flight work
                      re-queues per the recovery knobs); a replacement
                      spawns after ``duration`` of downtime.
``lose_shard``        kill EVERY replica of the fullest cache-holding
                      shard and wipe its cache segment (sharded pools).
``straggle_replica``  a random replica slows by ``factor``× for
                      ``duration`` (straggler, not a failure).
``kill_prefill`` / ``kill_decode``
                      fail-stop one instance (never the last alive one);
                      victims re-queue for re-prefill, their in-flight
                      pool probes are cancelled; revives after
                      ``duration``.
``straggle_decode``   one decode instance slows by ``factor``×.
``kv_degrade``        the prefill→decode KV link loses ``factor``× of
                      its bandwidth for ``duration``.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Callable, List, Optional, Tuple

import numpy as np

# fault kinds applicable to a bare vector pool vs a full cluster sim
POOL_KINDS = ("kill_replica", "lose_shard", "straggle_replica")
CLUSTER_KINDS = ("kill_prefill", "kill_decode", "straggle_decode",
                 "kv_degrade")

_SCHED_SALT = 0xC7A05  # schedule PRNG domain
_PICK_SALT = 0x1A57  # fire-time target-pick PRNG domain


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    t: float
    kind: str
    target: int = -1  # -1 => auto-pick at fire time
    factor: float = 1.0  # slowdown / bandwidth-division factor
    duration: float = 0.0  # straggle/degrade length, or kill downtime


def make_schedule(seed: int, t_start: float, t_end: float,
                  rates: dict, *, slow_factor: float = 8.0,
                  slow_duration: float = 0.05,
                  downtime: float = 0.1) -> List[FaultEvent]:
    """Draw a fault schedule over ``[t_start, t_end)``.

    ``rates`` maps fault kind → events/second; each kind is an
    independent Poisson process seeded by ``(seed, kind)``, so adding a
    kind (or changing its rate) never perturbs the arrival times of the
    others. Deterministic: same arguments, same schedule.
    """
    events: List[FaultEvent] = []
    for kind in sorted(rates):
        rate = rates[kind]
        if rate <= 0:
            continue
        assert kind in POOL_KINDS + CLUSTER_KINDS, kind
        salt = POOL_KINDS.index(kind) if kind in POOL_KINDS \
            else len(POOL_KINDS) + CLUSTER_KINDS.index(kind)
        rng = np.random.default_rng(
            np.random.SeedSequence([_SCHED_SALT, seed, salt]))
        slow = kind.startswith("straggle") or kind == "kv_degrade"
        t = t_start
        while True:
            t += rng.exponential(1.0 / rate)
            if t >= t_end:
                break
            events.append(FaultEvent(
                t=float(t), kind=kind,
                factor=slow_factor if slow else 1.0,
                duration=slow_duration if slow else downtime))
    events.sort(key=lambda e: (e.t, e.kind))
    return events


class ChaosInjector:
    """Replay a fault schedule against a pool or a cluster sim."""

    def __init__(self, schedule: List[FaultEvent], seed: int = 0):
        self.schedule = list(schedule)
        self._rng = np.random.default_rng(
            np.random.SeedSequence([_PICK_SALT, seed]))
        self.log: List[dict] = []  # one row per event: applied or skipped
        self.injected = 0

    def _note(self, ev: FaultEvent, target, applied: bool):
        self.log.append({"t": ev.t, "kind": ev.kind, "target": target,
                         "applied": applied})
        if applied:
            self.injected += 1

    # ------------------------------------------------------ pool targets
    def _apply_pool(self, pool, ev: FaultEvent,
                    t: float) -> List[Tuple[float, Callable]]:
        """Apply one pool-kind event; returns (time, fn) follow-ups."""
        if ev.kind == "kill_replica":
            sharded = getattr(pool, "shards", None) is not None
            if not sharded and len(pool.replicas) <= 1:
                # a monolithic pool's last replica has no re-home path
                self._note(ev, None, False)
                return []
            victim = pool.replicas[ev.target] if ev.target >= 0 else max(
                pool.replicas, key=lambda r: (len(r.in_flight), -r.rid))
            shard = victim.shard
            group = (lambda: pool.shard_replicas(shard)) if sharded \
                else (lambda: pool.replicas)
            n_before = len(group())
            pool.kill_replica(pool.replicas.index(victim))
            self._note(ev, victim.rid, True)

            def _respawn():
                # restore the PRE-KILL count only: an orphaned shard may
                # already have been auto-re-homed at kill time
                if len(group()) < n_before:
                    pool.spawn_replica(shard if sharded else None)
            return [(t + ev.duration, _respawn)]

        if ev.kind == "straggle_replica":
            i = ev.target if ev.target >= 0 \
                else int(self._rng.integers(len(pool.replicas)))
            rep = pool.replicas[i]
            rep.slowdown = ev.factor
            self._note(ev, rep.rid, True)
            # restore by identity: indices shift as replicas die/spawn,
            # and restoring a dead replica is a harmless no-op
            return [(t + ev.duration,
                     lambda: setattr(rep, "slowdown", 1.0))]

        if ev.kind == "lose_shard":
            if getattr(pool, "shards", None) is None:
                self._note(ev, None, False)  # monolithic: no shards
                return []
            cached = pool.shards.cache_shards()
            if ev.target >= 0:
                s = ev.target
            elif cached:  # the fullest cache-holding shard hurts most
                s = max(cached,
                        key=lambda c: (pool.shards.shards[c].cache_size, -c))
            else:
                s = int(self._rng.integers(pool.shards.num_shards))
            n_before = len(pool.shard_replicas(s))
            pool.lose_shard(s)
            self._note(ev, s, True)

            def _respawn(pool=pool, s=s, n=n_before):
                for _ in range(max(0, n - len(pool.shard_replicas(s)))):
                    pool.spawn_replica(s)
            return [(t + ev.duration, _respawn)]

        raise ValueError(f"not a pool fault kind: {ev.kind}")

    # ------------------------------------------------------ drive: pool
    def run_pool(self, pool, t_end: float):
        """Advance ``pool`` to ``t_end``, firing every pool-kind event
        (and its follow-ups) at its scheduled time."""
        heap: List[Tuple[float, int, Optional[FaultEvent],
                         Optional[Callable]]] = []
        seq = 0
        for ev in self.schedule:
            if ev.t < t_end and ev.kind in POOL_KINDS:
                heap.append((ev.t, seq, ev, None))
                seq += 1
        heapq.heapify(heap)
        while heap:
            t, _, ev, fn = heapq.heappop(heap)
            pool.run_until(t)
            followups = self._apply_pool(pool, ev, t) if ev is not None \
                else (fn() or [])
            for tf, f in followups:
                if tf < t_end:
                    heapq.heappush(heap, (tf, seq, None, f))
                    seq += 1
        pool.run_until(t_end)

    # --------------------------------------------------- drive: cluster
    def arm(self, sim):
        """Register every scheduled event on ``sim``'s event heap.

        Pool-kind events first advance the vector pool to the sim clock
        (pool time is polled lazily) so the fault lands at the right
        simulated instant; their follow-ups are scheduled back onto the
        sim heap too.
        """
        for ev in self.schedule:
            sim.schedule(ev.t, self._cluster_closure(sim, ev))

    def _cluster_closure(self, sim, ev: FaultEvent) -> Callable:
        def _fire():
            if ev.kind in POOL_KINDS:
                sim.vector_pool.run_until(sim.t_now)
                for tf, f in self._apply_pool(sim.vector_pool, ev,
                                              sim.t_now):
                    sim.schedule(tf, f)
                return
            self._apply_cluster(sim, ev)
        return _fire

    def _apply_cluster(self, sim, ev: FaultEvent):
        if ev.kind in ("kill_prefill", "kill_decode"):
            prefill = ev.kind == "kill_prefill"
            pool = sim.prefill_pool if prefill else sim.decode_pool
            load = (lambda i: len(i.current)) if prefill \
                else (lambda i: len(i.active))
            alive = [i for i, inst in enumerate(pool)
                     if inst.health.alive]
            if len(alive) <= 1:  # never kill the last serving path
                self._note(ev, None, False)
                return
            idx = ev.target if ev.target >= 0 \
                else max(alive, key=lambda i: (load(pool[i]), -i))
            (sim.kill_prefill(idx) if prefill else sim.kill_decode(idx))()
            revive = sim.revive_prefill(idx) if prefill \
                else sim.revive_decode(idx)
            sim.schedule(sim.t_now + ev.duration, revive)
            self._note(ev, idx, True)
        elif ev.kind == "straggle_decode":
            alive = [i for i, inst in enumerate(sim.decode_pool)
                     if inst.health.alive]
            if not alive:
                self._note(ev, None, False)
                return
            idx = ev.target if ev.target >= 0 \
                else int(self._rng.choice(alive))
            sim.set_decode_slowdown(idx, ev.factor)()
            sim.schedule(sim.t_now + ev.duration,
                         sim.set_decode_slowdown(idx, 1.0))
            self._note(ev, idx, True)
        elif ev.kind == "kv_degrade":
            sim.set_kv_bandwidth(1.0 / ev.factor)()
            sim.schedule(sim.t_now + ev.duration,
                         sim.set_kv_bandwidth(ev.factor))
            self._note(ev, None, True)
        else:  # pragma: no cover - schedule validated in make_schedule
            raise ValueError(f"unknown fault kind: {ev.kind}")
